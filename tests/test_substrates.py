"""Data pipeline, checkpoint store, optimizer, straggler detector."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.checkpoint import store
from repro.core.axes import mesh_info
from repro.data.pipeline import DataConfig, make_batch
from repro.models import params as prm
from repro.optim import adamw
from repro.runtime.trainer import StragglerDetector


# ---------------- data ----------------
def test_data_determinism():
    cfg = DataConfig(global_batch=4, seq_len=32, vocab_size=100)
    b1 = make_batch(cfg, 5)
    b2 = make_batch(cfg, 5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, 6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_data_labels_shifted():
    cfg = DataConfig(global_batch=2, seq_len=16, vocab_size=50, pack=False)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    # labels are next-token: reconstruct the underlying stream
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_data_microbatch_reshape():
    cfg = DataConfig(global_batch=8, seq_len=16, vocab_size=50, microbatch=4)
    b = make_batch(cfg, 0)
    assert b["tokens"].shape == (4, 2, 16)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 8))
def test_data_determinism_property(step, batch):
    cfg = DataConfig(global_batch=batch, seq_len=8, vocab_size=64)
    np.testing.assert_array_equal(make_batch(cfg, step)["tokens"],
                                  make_batch(cfg, step)["tokens"])
    assert make_batch(cfg, step)["tokens"].max() < 64


# ---------------- checkpoint ----------------
def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32), "d": None}}
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 3, tree, metadata={"loss": 1.5})
        assert store.latest_step(d) == 3
        out, meta = store.restore(d, 3, tree)
        np.testing.assert_array_equal(out["a"], tree["a"])
        np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])
        assert out["b"]["d"] is None
        assert meta["loss"] == 1.5


def test_checkpoint_gc_keeps_last_k():
    tree = {"a": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        for s in range(6):
            store.save(d, s, tree, keep_last=2)
        assert store.all_steps(d) == [4, 5]


def test_async_checkpointer():
    tree = {"a": jnp.arange(4.0)}
    with tempfile.TemporaryDirectory() as d:
        ck = store.AsyncCheckpointer(d, keep_last=3)
        ck.save(1, tree)
        ck.save(2, tree)       # waits for 1
        ck.wait()
        assert store.all_steps(d) == [1, 2]


def test_checkpoint_atomicity_no_tmp_left():
    tree = {"a": jnp.zeros((2,))}
    with tempfile.TemporaryDirectory() as d:
        store.save(d, 0, tree)
        assert not any(x.endswith(".tmp") for x in os.listdir(d))


# ---------------- optimizer ----------------
def _mesh11():
    from repro.core import compat
    return compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))


def test_adamw_decreases_quadratic_loss():
    mesh = _mesh11()
    info = mesh_info(mesh)
    from jax.sharding import PartitionSpec as P
    specs = {"w": prm.Spec((8,), P(None), jnp.float32)}
    params = {"w": jnp.full((8,), 5.0)}
    opt = adamw.init_opt_state(params, specs, info)
    cfg = adamw.AdamWConfig(learning_rate=0.1, warmup_steps=0,
                            weight_decay=0.0)
    def loss(p):
        return jnp.sum(p["w"] ** 2)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw.apply_updates(params, g, opt, cfg)
    assert float(loss(params)) < 0.1 * l0


def test_grad_clip_bounds_update():
    mesh = _mesh11()
    info = mesh_info(mesh)
    from jax.sharding import PartitionSpec as P
    specs = {"w": prm.Spec((4,), P(None), jnp.float32)}
    params = {"w": jnp.zeros((4,))}
    opt = adamw.init_opt_state(params, specs, info)
    cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=0,
                            grad_clip=1.0, weight_decay=0.0)
    g = {"w": jnp.full((4,), 1e6)}
    _, _, gnorm = adamw.apply_updates(params, g, opt, cfg)
    assert float(gnorm) > 1e5          # reported norm is pre-clip


def test_int8_compression_error_feedback():
    g = jnp.linspace(-1, 1, 64)
    deq, err = adamw.compress_int8(g, None)
    assert float(jnp.max(jnp.abs(deq - g))) < 1.0 / 127 + 1e-6
    # error feedback: residual carries what quantization dropped
    np.testing.assert_allclose(deq + err, g, atol=1e-6)


def test_lr_schedule_warmup_and_decay():
    cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=10,
                            total_steps=100)
    lrs = [float(adamw.lr_at(cfg, jnp.int32(s))) for s in (1, 10, 50, 100)]
    assert lrs[0] < lrs[1]
    assert lrs[1] >= lrs[2] >= lrs[3]
    assert lrs[3] >= 0.05


# ---------------- straggler detector ----------------
def test_straggler_detector_flags_outlier():
    det = StragglerDetector()
    for i in range(20):
        assert not det.observe(i, 1.0 + 0.01 * (i % 3))
    assert det.observe(20, 10.0)
    assert det.slow_steps and det.slow_steps[0][0] == 20
