# NOTE: deliberately no XLA_FLAGS here — smoke tests must see the real
# (1-device) topology.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (tests/_scripts/).
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def smoke_mesh():
    # compat.make_mesh drops axis_types on jax versions without AxisType
    from repro.core import compat
    return compat.make_mesh((1, 1), ("data", "model"),
                            axis_types=compat.auto_axis_types(2))


def subprocess_env():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    return env


def optional_hypothesis():
    """``(given, settings, st)`` from hypothesis, or decoration-safe stubs
    whose ``given`` marks the decorated test skipped — so missing the
    optional dep skips ONLY the property tests, not the module's plain
    tests (a module-level importorskip would take those down too)."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ModuleNotFoundError:
        def given(*_a, **_k):
            return lambda f: pytest.mark.skip(
                reason="needs hypothesis (pip install -r "
                       "requirements-dev.txt)")(f)

        def settings(*_a, **_k):
            return lambda f: f

        class _Strategies:
            def __getattr__(self, _name):
                return lambda *a, **k: None

        return given, settings, _Strategies()
