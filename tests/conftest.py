# NOTE: deliberately no XLA_FLAGS here — smoke tests must see the real
# (1-device) topology.  Multi-device tests spawn subprocesses that set
# --xla_force_host_platform_device_count themselves (tests/_scripts/).
import os
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture(scope="session")
def smoke_mesh():
    import jax
    from jax.sharding import AxisType
    return jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def subprocess_env():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.abspath(SRC)
    return env
