"""Model-component correctness: SSD chunked==sequential, RG-LRU scan==step,
prefill/decode consistency, attention causality & masking properties."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.models import rglru as rg
from repro.models import ssd as ssd_m
from repro.models.attention import chunked_attention, decode_attention
from repro.kernels import ref


def test_ssd_chunked_matches_sequential():
    k = jax.random.PRNGKey(0)
    ks = jax.random.split(k, 5)
    b, s, h, p, n = 2, 256, 3, 16, 8
    x = 0.5 * jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = 0.1 * jax.random.normal(ks[2], (h,))
    B = 0.3 * jax.random.normal(ks[3], (b, s, n))
    C = 0.3 * jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((h,))
    y1, s1 = ssd_m.ssd_chunked(x, dt, A_log, B, C, D, chunk=64)
    y2, s2 = ssd_m.ssd_sequential(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(y1, y2, atol=2e-4, rtol=1e-3)
    np.testing.assert_allclose(s1, s2, atol=2e-4, rtol=1e-3)


def test_ssd_step_matches_scan_tail():
    k = jax.random.PRNGKey(1)
    ks = jax.random.split(k, 5)
    b, s, h, p, n = 1, 33, 2, 8, 4
    x = 0.5 * jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = jnp.zeros((h,))
    B = 0.3 * jax.random.normal(ks[3], (b, s, n))
    C = 0.3 * jax.random.normal(ks[4], (b, s, n))
    D = jnp.zeros((h,))
    y_full, S_full = ssd_m.ssd_sequential(x, dt, A_log, B, C, D)
    # replay last step from the state after s-1 tokens
    y_pre, S_pre = ssd_m.ssd_sequential(x[:, :-1], dt[:, :-1], A_log,
                                        B[:, :-1], C[:, :-1], D)
    y_step, S_step = ssd_m.ssd_step(x[:, -1], dt[:, -1], A_log, B[:, -1],
                                    C[:, -1], D, S_pre)
    np.testing.assert_allclose(y_step, y_full[:, -1], atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(S_step, S_full, atol=1e-4, rtol=1e-3)


def test_rglru_scan_matches_steps():
    k = jax.random.PRNGKey(2)
    b, s, w = 2, 17, 8
    x = jax.random.normal(k, (b, s, w))
    p = {n: 0.5 * jax.random.normal(kk, (w,))
         for n, kk in zip(["w_a", "b_a", "w_x", "b_x", "a_param"],
                          jax.random.split(k, 5))}
    y_scan, h_last = rg.rglru_scan(x, p)
    h = jnp.zeros((b, w), jnp.float32)
    ys = []
    for t in range(s):
        y, h = rg.rglru_step(x[:, t:t + 1], p, h)
        ys.append(y)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(y_scan, y_steps, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(h_last, h, atol=1e-5, rtol=1e-4)


def test_attention_causality_property():
    """Perturbing future tokens must not change past outputs."""
    k = jax.random.PRNGKey(3)
    b, s, h, hd = 1, 64, 2, 16
    q = jax.random.normal(k, (b, s, h, hd))
    kk = jax.random.normal(jax.random.PRNGKey(4), (b, s, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, s, h, hd))
    o1 = chunked_attention(q, kk, v, causal=True, chunk=32)
    kk2 = kk.at[:, 40:].add(100.0)
    v2 = v.at[:, 40:].add(-50.0)
    o2 = chunked_attention(q, kk2, v2, causal=True, chunk=32)
    np.testing.assert_allclose(o1[:, :40], o2[:, :40], atol=1e-5)


def test_chunked_attention_matches_dense_ref():
    k = jax.random.PRNGKey(6)
    b, s, h, kvh, hd = 2, 96, 4, 2, 16
    q = jax.random.normal(k, (b, s, h, hd))
    kk = jax.random.normal(jax.random.PRNGKey(7), (b, s, kvh, hd))
    v = jax.random.normal(jax.random.PRNGKey(8), (b, s, kvh, hd))
    for kwargs in [dict(causal=True), dict(causal=True, window=24),
                   dict(causal=False), dict(causal=True, softcap=30.0)]:
        o = chunked_attention(q, kk, v, chunk=32, **kwargs)
        r = ref.attention_ref(q, kk, v, **kwargs)
        np.testing.assert_allclose(o, r, atol=2e-5, rtol=1e-4)


def test_decode_attention_matches_prefill_tail():
    k = jax.random.PRNGKey(9)
    b, s, h, hd = 2, 48, 2, 16
    q = jax.random.normal(k, (b, s, h, hd))
    kk = jax.random.normal(jax.random.PRNGKey(10), (b, s, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(11), (b, s, h, hd))
    full = chunked_attention(q, kk, v, causal=True, chunk=16)
    pos = jnp.full((b,), s - 1, jnp.int32)
    dec = decode_attention(q[:, -1:], kk, v, pos)
    np.testing.assert_allclose(dec[:, 0], full[:, -1], atol=1e-5, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.integers(1, 64), st.integers(1, 8))
def test_rmsnorm_scale_invariance(b, s, mult):
    """rmsnorm(c*x) == rmsnorm(x) for any positive scalar c (property)."""
    x = jax.random.normal(jax.random.PRNGKey(b * 100 + s), (b, s, 32))
    sc = jnp.zeros((32,))
    y1 = ref.rmsnorm_ref(x, sc)
    y2 = ref.rmsnorm_ref(x * mult, sc)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)
