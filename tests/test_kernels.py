"""Per-kernel interpret-mode validation: shape/dtype sweeps vs the ref.py
pure-jnp oracles (the required per-kernel allclose tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=1e-4)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,s,h,kvh,hd", [
    (1, 128, 2, 2, 64), (2, 256, 4, 2, 64), (1, 192, 6, 1, 32),
])
@pytest.mark.parametrize("kwargs", [
    dict(causal=True), dict(causal=True, window=64),
    dict(causal=False), dict(causal=True, softcap=25.0),
])
def test_flash_attention_sweep(dtype, b, s, h, kvh, hd, kwargs):
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (b, s, h, hd), dtype)
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, hd), dtype)
    o = ops.flash_attention(q, kk, v, interpret=True, block_q=64,
                            block_k=64, **kwargs)
    r = ref.attention_ref(q, kk, v, **kwargs)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,s,h,kvh,hd,kwargs", [
    # seq NOT divisible by the 64-wide blocks: masked tail tiles must not
    # leak into the online softmax
    (1, 100, 2, 2, 64, dict(causal=True)),
    (2, 80, 4, 2, 32, dict(causal=True, window=24)),
    # sliding window narrower than one KV block: the live band is a
    # sub-block diagonal strip, so block-skip must keep partial blocks
    (1, 160, 4, 1, 64, dict(causal=True, window=16)),
    # logit softcap composed with grouped-query heads
    (1, 128, 8, 2, 64, dict(causal=True, softcap=30.0)),
    (2, 96, 8, 2, 32, dict(causal=True, window=48, softcap=30.0)),
])
def test_flash_attention_edge_cases(dtype, b, s, h, kvh, hd, kwargs):
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, h, hd), dtype)
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, hd), dtype)
    o = ops.flash_attention(q, kk, v, interpret=True, block_q=64,
                            block_k=64, **kwargs)
    r = ref.attention_ref(q, kk, v, **kwargs)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("rows,d", [(64, 128), (33, 256), (257, 512)])
def test_rmsnorm_sweep(dtype, rows, d):
    x = jax.random.normal(jax.random.PRNGKey(3), (rows, d), dtype)
    s = 0.1 * jax.random.normal(jax.random.PRNGKey(4), (d,), jnp.float32)
    o = ops.rmsnorm(x, s, interpret=True, block_rows=64)
    r = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("b,s,w", [(1, 128, 512), (2, 64, 1024)])
def test_rglru_sweep(dtype, b, s, w):
    x = jax.random.normal(jax.random.PRNGKey(5), (b, s, w), dtype)
    p = {n: 0.5 * jax.random.normal(kk, (w,))
         for n, kk in zip(["w_a", "b_a", "w_x", "b_x", "a_param"],
                          jax.random.split(jax.random.PRNGKey(6), 5))}
    y, h = ops.rglru(x, p, interpret=True, block_t=32, block_w=256)
    yr, hr = ref.rglru_ref(x, p)
    np.testing.assert_allclose(y, yr, atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(h, hr, atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 128, 2, 32, 16, 64), (2, 256, 4, 64, 32, 128),
])
def test_ssd_sweep(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = 0.5 * jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A_log = 0.1 * jax.random.normal(ks[2], (h,))
    B = 0.3 * jax.random.normal(ks[3], (b, s, n))
    C = 0.3 * jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((h,))
    y = ops.ssd(x, dt, A_log, B, C, D, chunk=chunk, interpret=True)
    yr, _ = ref.ssd_ref(x, dt, A_log, B, C, D)
    np.testing.assert_allclose(y, yr, atol=5e-4, rtol=5e-3)


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("e,c,d,f", [(2, 128, 256, 128), (4, 256, 512, 256)])
def test_moe_gmm_sweep(dtype, e, c, d, f):
    x = jax.random.normal(jax.random.PRNGKey(8), (e, c, d), dtype)
    w = 0.05 * jax.random.normal(jax.random.PRNGKey(9), (e, d, f), dtype)
    o = ops.moe_gmm(x, w, interpret=True)
    r = ref.moe_gmm_ref(x, w)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))
