"""Golden regression: pin the Planner-v2 decisions on the two fixture
HWConfigs so cost-model edits that silently flip Table-6-style plans fail
loudly.

Fixtures (core/planner/costmodel.py):
* ``COMMODITY_25GBE`` — two 8-GPU boxes over a 25 GbE NIC (the paper's
  commodity-server regime, heterogeneous per-axis bandwidths);
* ``NVLINK_BOX``      — one 16-GPU NVLink-class box (uniform fast links).

If an intentional cost-model change moves a pinned plan, re-derive the
goldens by running the printed `plan()` calls and update this file in the
same commit — the point is that the move is *visible*.
"""
import pytest

from repro.configs.base import TrainHParams
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.core.planner import COMMODITY_25GBE, NVLINK_BOX, plan


def _case(schedule, hw, **kw):
    cfg, _tmp, _dp, gb = PAPER_TABLE4["gpt-h8192"]
    return plan(cfg, paper_shape(gb), TrainHParams(schedule=schedule), hw,
                **kw)


# (schedule, fixture, plan kwargs) -> expected uniform degree
FREE_SPACE_GOLDEN = {
    ("oases", "25gbe"): 2,
    ("oases", "nvlink"): 2,
    ("fused", "25gbe"): 4,
    ("fused", "nvlink"): 8,
}
# options pinned to the full 16-way group: the memory-bound regime where
# the 1D ring must cross the NIC and the 2D hybrid pays off
TIGHT_GOLDEN = {
    ("oases", "25gbe"): (8, 2),
    ("oases", "nvlink"): 16,
    ("fused", "25gbe"): (8, 2),
    ("fused", "nvlink"): 16,
}
HW = {"25gbe": COMMODITY_25GBE, "nvlink": NVLINK_BOX}


@pytest.mark.parametrize("schedule", ["oases", "fused"])
@pytest.mark.parametrize("fixture", ["25gbe", "nvlink"])
def test_free_space_plan_pinned(schedule, fixture):
    r = _case(schedule, HW[fixture], layout="auto")
    expect = FREE_SPACE_GOLDEN[(schedule, fixture)]
    assert r.degrees == [expect] * len(r.degrees), r.summary()
    assert r.status == "0", r.summary()


@pytest.mark.parametrize("schedule", ["oases", "fused"])
@pytest.mark.parametrize("fixture", ["25gbe", "nvlink"])
def test_spanning_regime_plan_pinned(schedule, fixture):
    r = _case(schedule, HW[fixture], options=(16,), layout="auto")
    expect = TIGHT_GOLDEN[(schedule, fixture)]
    assert r.degrees == [expect] * len(r.degrees), r.summary()


@pytest.mark.parametrize("schedule", ["oases", "fused"])
def test_2d_wins_on_commodity_loses_nothing_on_nvlink(schedule):
    """The acceptance shape of the whole feature: when the group must span
    both commodity nodes, the hybrid beats 1D by a wide margin; on the
    uniform NVLink box the 2D search space changes nothing."""
    p1 = _case(schedule, COMMODITY_25GBE, options=(16,), layout="1d")
    p2 = _case(schedule, COMMODITY_25GBE, options=(16,), layout="auto")
    assert p2.predicted_s < p1.predicted_s * 0.8, (p1.summary(),
                                                  p2.summary())
    n1 = _case(schedule, NVLINK_BOX, options=(16,), layout="1d")
    n2 = _case(schedule, NVLINK_BOX, options=(16,), layout="auto")
    assert n2.predicted_s == pytest.approx(n1.predicted_s, rel=1e-9)


@pytest.mark.parametrize("fixture", ["25gbe", "nvlink"])
@pytest.mark.parametrize("schedule", ["oases", "fused", "megatron"])
def test_2d_never_worse_than_1d(schedule, fixture):
    """PR acceptance: plan() with 2D enabled returns a plan whose modeled
    iteration time is <= the best 1D plan on both fixture HWConfigs."""
    p1 = _case(schedule, HW[fixture], layout="1d")
    p2 = _case(schedule, HW[fixture], layout="auto")
    assert p2.predicted_s <= p1.predicted_s * (1 + 1e-9), (p1.summary(),
                                                           p2.summary())
