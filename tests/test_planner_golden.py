"""Golden regression: pin the Planner-v2 decisions on the two fixture
HWConfigs so cost-model edits that silently flip Table-6-style plans fail
loudly.

Fixtures (core/planner/costmodel.py):
* ``COMMODITY_25GBE`` — two 8-GPU boxes over a 25 GbE NIC (the paper's
  commodity-server regime, heterogeneous per-axis bandwidths);
* ``NVLINK_BOX``      — one 16-GPU NVLink-class box (uniform fast links).

If an intentional cost-model change moves a pinned plan, re-derive the
goldens by running the printed `plan()` calls and update this file in the
same commit — the point is that the move is *visible*.
"""
import pytest

from repro.configs.base import SHAPES, ShapeConfig, TrainHParams
from repro.configs.gpt_oases import PAPER_TABLE4, paper_shape
from repro.configs.registry import get_config
from repro.core.planner import (COMMODITY_25GBE, NVLINK_BOX,
                                decode_step_time, estimate_iteration, plan,
                                plan_serving)
from repro.core.schedule import SCHEDULES


def _case(schedule, hw, **kw):
    cfg, _tmp, _dp, gb = PAPER_TABLE4["gpt-h8192"]
    return plan(cfg, paper_shape(gb), TrainHParams(schedule=schedule), hw,
                **kw)


# (schedule, fixture, plan kwargs) -> expected uniform degree
FREE_SPACE_GOLDEN = {
    ("oases", "25gbe"): 2,
    ("oases", "nvlink"): 2,
    ("fused", "25gbe"): 4,
    ("fused", "nvlink"): 8,
}
# options pinned to the full 16-way group: the memory-bound regime where
# the 1D ring must cross the NIC and the 2D hybrid pays off
TIGHT_GOLDEN = {
    ("oases", "25gbe"): (8, 2),
    ("oases", "nvlink"): 16,
    ("fused", "25gbe"): (8, 2),
    ("fused", "nvlink"): 16,
}
HW = {"25gbe": COMMODITY_25GBE, "nvlink": NVLINK_BOX}


@pytest.mark.parametrize("schedule", ["oases", "fused"])
@pytest.mark.parametrize("fixture", ["25gbe", "nvlink"])
def test_free_space_plan_pinned(schedule, fixture):
    r = _case(schedule, HW[fixture], layout="auto")
    expect = FREE_SPACE_GOLDEN[(schedule, fixture)]
    assert r.degrees == [expect] * len(r.degrees), r.summary()
    assert r.status == "0", r.summary()


@pytest.mark.parametrize("schedule", ["oases", "fused"])
@pytest.mark.parametrize("fixture", ["25gbe", "nvlink"])
def test_spanning_regime_plan_pinned(schedule, fixture):
    r = _case(schedule, HW[fixture], options=(16,), layout="auto")
    expect = TIGHT_GOLDEN[(schedule, fixture)]
    assert r.degrees == [expect] * len(r.degrees), r.summary()


@pytest.mark.parametrize("schedule", ["oases", "fused"])
def test_2d_wins_on_commodity_loses_nothing_on_nvlink(schedule):
    """The acceptance shape of the whole feature: when the group must span
    both commodity nodes, the hybrid beats 1D by a wide margin; on the
    uniform NVLink box the 2D search space changes nothing."""
    p1 = _case(schedule, COMMODITY_25GBE, options=(16,), layout="1d")
    p2 = _case(schedule, COMMODITY_25GBE, options=(16,), layout="auto")
    assert p2.predicted_s < p1.predicted_s * 0.8, (p1.summary(),
                                                  p2.summary())
    n1 = _case(schedule, NVLINK_BOX, options=(16,), layout="1d")
    n2 = _case(schedule, NVLINK_BOX, options=(16,), layout="auto")
    assert n2.predicted_s == pytest.approx(n1.predicted_s, rel=1e-9)


@pytest.mark.parametrize("fixture", ["25gbe", "nvlink"])
@pytest.mark.parametrize("schedule", ["oases", "fused", "megatron"])
def test_2d_never_worse_than_1d(schedule, fixture):
    """PR acceptance: plan() with 2D enabled returns a plan whose modeled
    iteration time is <= the best 1D plan on both fixture HWConfigs."""
    p1 = _case(schedule, HW[fixture], layout="1d")
    p2 = _case(schedule, HW[fixture], layout="auto")
    assert p2.predicted_s <= p1.predicted_s * (1 + 1e-9), (p1.summary(),
                                                           p2.summary())


# --------------------------------------------------------------------------
# per-layer (degree, schedule) search (the executable-plan tentpole)
# --------------------------------------------------------------------------
# The regime where the paper's REAL per-layer search space pays: on the
# commodity fixture with the memory cap between uniform-8 and uniform-16,
# the ILP parks part of the stack at degree 16 (whose ring crosses the
# NIC, where wang's intra-op chunking is the only schedule that keeps the
# exposed comm sane) and keeps the rest at the intra-node degree 8 (where
# barrier-free oases is compute-bound and strictly best).  No uniform
# SCHEDULE can do both: the mixed (degree, schedule) plan must be strictly
# cheaper than every uniform-schedule alternative searched over the same
# degree space.  llama-3.2-vision-11b is the heterogeneous-layer-shape
# config (cross-attn every 5th layer doubles those layers' attention
# params), which is what lets the ILP choose WHICH layers to park at 16.
MIXED_CASES = {
    # arch -> (mem_cap, pinned {(degree, schedule): layer count})
    "llama-3.2-vision-11b": (18.5e9, {(8, "oases"): 28, (16, "wang"): 12}),
    "granite-moe-3b-a800m": (5.6e9, {(8, "oases"): 18, (16, "wang"): 14}),
}


def _mixed_case(arch):
    cap, expect = MIXED_CASES[arch]
    cfg = get_config(arch)
    r = plan(cfg, SHAPES["train_4k"], TrainHParams(), COMMODITY_25GBE,
             options=(8, 16), mem_cap=cap, schedules="auto",
             time_limit=30.0)
    return cfg, cap, expect, r


@pytest.mark.parametrize("arch", sorted(MIXED_CASES))
def test_mixed_schedule_plan_pinned(arch):
    cfg, cap, expect, r = _mixed_case(arch)
    got = {}
    for d, s in zip(r.degrees, r.schedules):
        key = (d if isinstance(d, int) else tuple(d), s)
        got[key] = got.get(key, 0) + 1
    assert got == expect, r.summary()
    assert r.status == "0", r.summary()
    # the result IS an executable plan (per-layer strategies, serializable)
    assert r.plan is not None and r.plan.is_mixed
    from repro.core.plan import ParallelPlan
    assert ParallelPlan.from_json(r.plan.to_json()) == r.plan


@pytest.mark.parametrize("arch", sorted(MIXED_CASES))
def test_mixed_schedule_beats_every_uniform_schedule(arch):
    """The tentpole acceptance: the mixed-(degree, schedule) plan is
    strictly cheaper in modeled time than the best plan of EVERY uniform
    schedule over the same (options, memory-cap) search space."""
    cfg, cap, _expect, r = _mixed_case(arch)
    assert len(set(r.schedules)) > 1, r.summary()
    for s in SCHEDULES:
        u = plan(cfg, SHAPES["train_4k"], TrainHParams(), COMMODITY_25GBE,
                 options=(8, 16), mem_cap=cap, schedules=(s,),
                 time_limit=30.0)
        assert r.predicted_s < u.predicted_s, (s, r.summary(), u.summary())
        # and the uniform alternative's own estimate agrees (the winner is
        # not an artifact of a disagreement between ILP and estimator)
        ue = estimate_iteration(cfg, SHAPES["train_4k"], TrainHParams(),
                                u.degrees, COMMODITY_25GBE,
                                schedules=[s] * cfg.num_layers)
        assert r.predicted_s < ue["iter_s"] * (1 + 1e-9)


def test_schedule_search_defaults_unchanged():
    """schedules=None must reproduce the pre-pair search exactly — the
    FREE_SPACE/TIGHT goldens above already pin this; here the explicit
    single-schedule tuple must agree with the default too."""
    cfg, _tmp, _dp, gb = PAPER_TABLE4["gpt-h8192"]
    a = plan(cfg, paper_shape(gb), TrainHParams(), COMMODITY_25GBE)
    b = plan(cfg, paper_shape(gb), TrainHParams(), COMMODITY_25GBE,
             schedules=("oases",))
    assert a.degrees == b.degrees
    assert a.predicted_s == pytest.approx(b.predicted_s, rel=1e-12)


def test_mixed_schedule_estimate_exposes_transition():
    """At a transition out of an oases overlap run the pending collective
    is exposed — a mixed estimate can never beat the sum of its parts'
    overlap assumptions by accounting sleight of hand."""
    cfg = get_config("granite-moe-3b-a800m")
    hp = TrainHParams()
    L = cfg.num_layers
    half = L // 2
    mixed = estimate_iteration(
        cfg, SHAPES["train_4k"], hp, [8] * L, COMMODITY_25GBE,
        schedules=["oases"] * half + ["megatron"] * (L - half))
    uni_o = estimate_iteration(cfg, SHAPES["train_4k"], hp, [8] * L,
                               COMMODITY_25GBE,
                               schedules=["oases"] * L)
    uni_m = estimate_iteration(cfg, SHAPES["train_4k"], hp, [8] * L,
                               COMMODITY_25GBE,
                               schedules=["megatron"] * L)
    assert uni_o["iter_s"] <= mixed["iter_s"] <= uni_m["iter_s"]


# --------------------------------------------------------------------------
# seq axis: ring attention in the per-layer search (seq="auto")
# --------------------------------------------------------------------------
# The regime where the plan's THIRD axis pays: long context (32k) on the
# commodity fixture with the memory cap below every head-sharded option.
# At one-sample microbatches the gathered-sequence residuals dominate
# Eq. 6 and no degree can shrink them — head-sharding divides weights,
# not saved activations — so every degree-only plan is infeasible and the
# ILP falls back to the NIC-spanning uniform-16 (250 s/iter).  Ring
# attention shards the sequence itself: the (1 - 1/n) residual saving
# buys back the replicated attention weights at d_model = 2048, and the
# KV ring hides under the attention block, so the seq-enabled search
# keeps the whole stack on fast intra-node degree 8.
SEQ_ARCH = "internlm2-1.8b"
SEQ_CAP = 10.8e9
# (degree, schedule, seq) -> layer count; ring layers consolidated to the
# tail of the stack (_consolidate_seqs), count set by the memory row
SEQ_GOLDEN = {(8, "oases", 1): 11, (8, "oases", 8): 13}


def _seq_case(seq):
    cfg = get_config(SEQ_ARCH)
    return cfg, plan(cfg, SHAPES["prefill_32k"], TrainHParams(),
                     COMMODITY_25GBE, options=(8, 16), mem_cap=SEQ_CAP,
                     schedules="auto", seq=seq, time_limit=30.0)


def test_seq_axis_plan_pinned():
    cfg, r = _seq_case("auto")
    got = {}
    for d, s, q in zip(r.degrees, r.schedules, r.seqs):
        key = (d if isinstance(d, int) else tuple(d), s, q)
        got[key] = got.get(key, 0) + 1
    assert got == SEQ_GOLDEN, r.summary()
    assert r.status == "0", r.summary()
    # ring layers are consolidated into one contiguous tail run
    assert r.seqs == sorted(r.seqs), r.seqs
    # the result IS an executable plan: mesh-following degrees on the
    # plain (data, model) mesh, the seq axis pinned per layer
    assert r.plan is not None and r.plan.planned_seqs == tuple(r.seqs)
    assert all(ls.degree is None for ls in r.plan.layers)
    assert r.plan.mesh_shape and r.plan.mesh_axes[-1] == "model"
    from repro.core.plan import ParallelPlan
    assert ParallelPlan.from_json(r.plan.to_json()) == r.plan


def test_seq_axis_beats_every_degree_only_plan():
    """The acceptance shape of the seq axis: under the long-context
    memory cap the seq-sharded plan is feasible and far cheaper than the
    best the degree-only search can do (which is infeasible here and
    falls back to the NIC-spanning uniform max degree)."""
    cfg, r = _seq_case("auto")
    assert any(q > 1 for q in r.seqs), r.summary()
    d = _seq_case("none")[1]
    assert d.status.startswith("fallback"), d.summary()
    assert r.predicted_s < 0.5 * d.predicted_s, (r.summary(), d.summary())
    # and the estimator agrees with the pinned decision's feasibility
    est = estimate_iteration(cfg, SHAPES["prefill_32k"], TrainHParams(),
                             r.degrees, COMMODITY_25GBE, options=(8, 16),
                             schedules=r.schedules, seqs=r.seqs)
    assert est["iter_s"] == pytest.approx(r.predicted_s, rel=1e-9)


def test_seq_axis_idle_on_free_memory():
    """With the cap lifted, ring stays off: head-sharding is modeled as
    no slower and the tie-break prefers seq == 1, so seq='auto' must
    reproduce the degree-only decision exactly."""
    cfg = get_config(SEQ_ARCH)
    a = plan(cfg, SHAPES["prefill_32k"], TrainHParams(), COMMODITY_25GBE,
             options=(8, 16), schedules="auto", time_limit=30.0)
    b = plan(cfg, SHAPES["prefill_32k"], TrainHParams(), COMMODITY_25GBE,
             options=(8, 16), schedules="auto", seq="auto",
             time_limit=30.0)
    assert (a.degrees, a.schedules) == (b.degrees, b.schedules)
    assert all(q == 1 for q in b.seqs)
    assert a.predicted_s == pytest.approx(b.predicted_s, rel=1e-12)


def test_seq_transitions_charged():
    """Every seq-axis boundary costs a residual regather: a fragmented
    ring assignment must estimate strictly worse than the same ring
    count consolidated into one run."""
    cfg = get_config(SEQ_ARCH)
    L = cfg.num_layers
    frag = [8 if i % 2 else 1 for i in range(L)]
    cons = sorted(frag)
    e_frag = estimate_iteration(cfg, SHAPES["prefill_32k"], TrainHParams(),
                                [8] * L, COMMODITY_25GBE, options=(8,),
                                seqs=frag)
    e_cons = estimate_iteration(cfg, SHAPES["prefill_32k"], TrainHParams(),
                                [8] * L, COMMODITY_25GBE, options=(8,),
                                seqs=cons)
    assert e_cons["iter_s"] < e_frag["iter_s"]
    assert e_cons["mem_bytes"] == pytest.approx(e_frag["mem_bytes"])


def test_seq_axis_param_validation():
    cfg = get_config(SEQ_ARCH)
    with pytest.raises(ValueError, match="seq"):
        plan(cfg, SHAPES["prefill_32k"], TrainHParams(), COMMODITY_25GBE,
             seq="wat")


# --------------------------------------------------------------------------
# serving latency objective (plan(objective="latency") -> plan_serving)
# --------------------------------------------------------------------------
# The latency regime: a handful of concurrent decode slots at moderate KV
# context, where the per-token collectives are LATENCY-bound (kilobyte
# payloads) and the matmuls are weight-streaming-bound.  On the commodity
# fixture a 16-way 1D ring pays NIC crossings every layer, so the hybrid
# keeps the wide x-ring on the intra-node fabric; on the NVLink box the
# switched fabric makes the 1D ring strictly cheapest.
SERVE_SHAPE = ShapeConfig("serve_b8_4k", 4096, 8, "decode")
# (fixture) -> expected (degree, pp) with options pinned to the full
# 16-way group (the spanning regime, as in TIGHT_GOLDEN above)
SERVING_GOLDEN = {
    "25gbe": ((8, 2), 1),
    "nvlink": (16, 1),
}


def _serve_case(fixture, **kw):
    cfg, _tmp, _dp, _gb = PAPER_TABLE4["gpt-h8192"]
    return plan(cfg, SERVE_SHAPE, TrainHParams(schedule="fused"),
                HW[fixture], options=(16,), objective="latency", **kw)


@pytest.mark.parametrize("fixture", ["25gbe", "nvlink"])
def test_serving_latency_plan_pinned(fixture):
    """The acceptance shape of the latency objective: a non-trivial
    (dx, dy, pp) choice on COMMODITY_25GBE, 1D on NVLINK_BOX."""
    r = _serve_case(fixture)
    degree, pp = SERVING_GOLDEN[fixture]
    assert (r.degree, r.pp) == (degree, pp), r.summary()
    assert r.fits, r.summary()


def test_serving_hybrid_wins_on_commodity_only():
    cfg = PAPER_TABLE4["gpt-h8192"][0]
    hp = TrainHParams(schedule="fused")
    c_1d = decode_step_time(cfg, SERVE_SHAPE, hp, COMMODITY_25GBE, 16)
    c_2d = decode_step_time(cfg, SERVE_SHAPE, hp, COMMODITY_25GBE, (8, 2))
    assert c_2d["step_s"] < c_1d["step_s"] * 0.95, (c_1d, c_2d)
    n_1d = decode_step_time(cfg, SERVE_SHAPE, hp, NVLINK_BOX, 16)
    n_2d = decode_step_time(cfg, SERVE_SHAPE, hp, NVLINK_BOX, (8, 2))
    assert n_1d["step_s"] < n_2d["step_s"], (n_1d, n_2d)


def test_serving_fused_no_slower_than_blocking():
    """The fused rings hide the bandwidth component under the decode
    matmuls; the blocking schedule exposes it — fused must never lose."""
    cfg = PAPER_TABLE4["gpt-h8192"][0]
    for hw in (COMMODITY_25GBE, NVLINK_BOX):
        for deg in (16, (8, 2)):
            f = decode_step_time(cfg, SERVE_SHAPE,
                                 TrainHParams(schedule="fused"), hw, deg)
            m = decode_step_time(cfg, SERVE_SHAPE,
                                 TrainHParams(schedule="megatron"), hw, deg)
            assert f["step_s"] <= m["step_s"] + 1e-12, (deg, f, m)


def test_serving_plan_objective_validation():
    cfg = PAPER_TABLE4["gpt-h8192"][0]
    with pytest.raises(ValueError, match="objective"):
        plan(cfg, SERVE_SHAPE, TrainHParams(), COMMODITY_25GBE,
             objective="wat")


def test_serving_pp_candidates_searched():
    """plan_serving with pp forced on returns an executable pipeline
    candidate (per-stage degree x stages == total capacity) and reports
    the TMP-only baseline it was compared against."""
    cfg = PAPER_TABLE4["gpt-h8192"][0]
    r = plan_serving(cfg, SERVE_SHAPE, TrainHParams(schedule="fused"),
                     COMMODITY_25GBE, options=(16,), pp_options=(2,))
    from repro.core.planner.costmodel import _dtot
    assert r.pp == 2 and _dtot(r.degree) * r.pp == 16, r.summary()
    assert r.n_micro >= 1 and r.predicted_s > 0


# --------------------------------------------------------------------------
# speculative decoding depth (plan_serving spec_options)
# --------------------------------------------------------------------------
# The spec trade: a round costs (k+1) replicated draft forwards plus one
# (k+1)-token verify, and emits E = (1-a^(k+1))/(1-a) expected tokens.
# What the verify amortizes is the target's per-layer collective LATENCY
# floor — large on the commodity fixture (every layer pays cross-box y
# hops), near-zero on the NVLink box — while the draft's replicated weight
# stream is the same on both.  So the same draft is worth k>1 on
# COMMODITY_25GBE and nothing on NVLINK_BOX.
SPEC_GOLDEN_KS = (0, 1, 2, 3, 4)


def _spec_case(hw):
    return plan_serving(get_config("gpt-serve-h4096"), SERVE_SHAPE,
                        TrainHParams(schedule="fused"), hw, options=(16,),
                        pp_options=(1,), spec_options=SPEC_GOLDEN_KS,
                        draft=get_config("gpt-draft-h2048"))


def test_spec_k_golden_commodity_drafts():
    r = _spec_case(COMMODITY_25GBE)
    assert r.spec_k > 1, r.summary()
    assert r.fits, r.summary()
    # and the spec plan genuinely beats the undrafted baseline
    assert r.predicted_s < r.tmp_only_s, r.summary()


def test_spec_k_golden_nvlink_stays_undrafted():
    r = _spec_case(NVLINK_BOX)
    assert r.spec_k <= 1, r.summary()


def test_spec_round_amortizes_latency_not_weights():
    """decode_step_time(spec_k=k): the per-token equivalent divides the
    round by E, so it must (a) beat the undrafted step on the commodity
    fixture, (b) never report a verify cheaper than physically possible
    (round > undrafted step: the verify still streams all the weights)."""
    cfg = get_config("gpt-serve-h4096")
    draft = get_config("gpt-draft-h2048")
    hp = TrainHParams(schedule="fused")
    base = decode_step_time(cfg, SERVE_SHAPE, hp, COMMODITY_25GBE, (8, 2))
    spec = decode_step_time(cfg, SERVE_SHAPE, hp, COMMODITY_25GBE, (8, 2),
                            spec_k=3, draft=draft)
    assert spec["step_s"] < base["step_s"], (base, spec)
    assert spec["e_tokens"] > 1.0
    round_s = spec["step_s"] * spec["e_tokens"]
    assert round_s > base["step_s"], (round_s, base)
    # draft memory (replicated weights + dense KV) is accounted
    assert spec["mem_bytes"] > base["mem_bytes"]


def test_spec_requires_draft_and_rejects_pp():
    cfg = get_config("gpt-serve-h4096")
    with pytest.raises(ValueError, match="draft"):
        decode_step_time(cfg, SERVE_SHAPE, TrainHParams(), COMMODITY_25GBE,
                         16, spec_k=2)
    with pytest.raises(ValueError, match="pipe|pipeline"):
        decode_step_time(cfg, SERVE_SHAPE, TrainHParams(), COMMODITY_25GBE,
                         8, pp=2, spec_k=2,
                         draft=get_config("gpt-draft-h2048"))
    with pytest.raises(ValueError, match="draft"):
        plan_serving(cfg, SERVE_SHAPE, TrainHParams(), COMMODITY_25GBE,
                     options=(16,), spec_options=(0, 2))


def test_paged_gather_discount_monotone():
    """Smaller pages pay more DMA startups: step time must be monotone
    non-increasing in page_size and equal the dense path at 0."""
    cfg = PAPER_TABLE4["gpt-h8192"][0]
    hp = TrainHParams(schedule="fused")
    prev = None
    for ps in (4, 16, 64, 256):
        t = decode_step_time(cfg, SERVE_SHAPE, hp, COMMODITY_25GBE, (8, 2),
                             page_size=ps)["step_s"]
        if prev is not None:
            assert t <= prev + 1e-15, (ps, t, prev)
        prev = t
    dense = decode_step_time(cfg, SERVE_SHAPE, hp, COMMODITY_25GBE,
                             (8, 2))["step_s"]
    # dense (page_size=0) is the lower bound: every paged variant pays
    # some gather startup, and tiny pages pay a visible one
    assert dense <= prev + 1e-15
    assert dense < decode_step_time(cfg, SERVE_SHAPE, hp, COMMODITY_25GBE,
                                    (8, 2), page_size=4)["step_s"]
